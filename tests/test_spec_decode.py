"""Speculative decoding: kernel gate, accept rule, engine equivalence.

Contract chain, weakest to strongest:
  1. multi-query verify kernel (interpret) == jnp ref oracle == the
     single-query oracle row by row (each q row at its own length);
  2. verify_accept implements exact-match coupling: leading matched
     prefix + correction token, capped at num_drafts;
  3. Engine equivalence: SpecDecodeBackend output is BIT-IDENTICAL to
     PagedBackend for any SamplingParams (greedy and seeded), on
     attention-only AND recurrent architectures, with accepting and
     fully-rejecting drafters — the RNG-stream contract makes the
     rejection rule exact, not merely distribution-preserving;
  4. scheduler invariants survive speculation: zero block leaks after
     rejected-tail rewinds, under preemption pressure, and with
     mid-window stop tokens;
  5. drafter behavior: ngram prompt-lookup finds repetitions (high
     acceptance on repetitive prompts), the draft-model drafter stays
     in sync through accept/reject/preempt cycles.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.launch.engine import (Engine, EngineConfig, NgramDrafter,
                                 SamplingParams, SpecDecodeBackend)
from repro.launch.engine.sampling import verify_accept
from repro.models.model import Model

GREEDY = SamplingParams(max_tokens=12)
SEEDED = SamplingParams(max_tokens=12, temperature=0.9, top_k=30,
                        top_p=0.95, seed=7)


def _cfg(**kw):
    kw.setdefault("backend", "paged")
    kw.setdefault("num_slots", 4)
    kw.setdefault("num_blocks", 32)
    kw.setdefault("block_size", 4)
    kw.setdefault("max_len", 64)
    return EngineConfig(**kw)


def _model(arch):
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _prompts(cfg, rng, n=5, repetitive=False):
    if repetitive:
        return [(list(rng.integers(0, cfg.vocab_size, 3)) * 6)[:10 + i]
                for i in range(n)]
    return [list(rng.integers(0, cfg.vocab_size, int(ln)))
            for ln in rng.integers(5, 14, n)]


class GarbageDrafter(NgramDrafter):
    """Adversarial drafter: random proposals, ~0% acceptance — every
    verify step exercises the rejected-tail rewind."""

    def propose(self, active, last_tokens, histories):
        rng = np.random.default_rng(sum(map(len, histories.values())))
        return {i: [int(x) for x in rng.integers(0, 256, self.k)]
                for i in active}


# -- 1. kernel vs oracle ------------------------------------------------


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("window", [None, 5])
def test_verify_kernel_matches_ref(rng, hq, hkv, window):
    B, K1, hd, bs, nbmax = 4, 4, 16, 4, 5
    nb = B * nbmax + 1
    q = jnp.asarray(rng.normal(size=(B, K1, hq, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    perm = rng.permutation(nb - 1) + 1
    bt = jnp.asarray(perm[:B * nbmax].reshape(B, nbmax), jnp.int32)
    # window start lengths: zero, mid-block, block boundary, deep
    ln = jnp.asarray([0, 3, 8, 14], jnp.int32)
    want = ref.paged_verify_attention(q, kp, vp, bt, ln, window=window)
    got = ops.paged_verify_attention(q, kp, vp, bt, ln, window=window,
                                     mode="interpret")
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_verify_ref_matches_single_query_rows(rng):
    """Row j of the multi-query oracle == the single-query decode oracle
    at length lengths + 1 + j (the per-row causal contract)."""
    B, K1, hq, hkv, hd, bs, nbmax = 3, 3, 4, 2, 8, 4, 4
    nb = B * nbmax + 1
    q = jnp.asarray(rng.normal(size=(B, K1, hq, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    perm = rng.permutation(nb - 1) + 1
    bt = jnp.asarray(perm[:B * nbmax].reshape(B, nbmax), jnp.int32)
    ln = jnp.asarray([2, 7, 0], jnp.int32)
    multi = ref.paged_verify_attention(q, kp, vp, bt, ln)
    for j in range(K1):
        single = ref.paged_decode_attention(q[:, j], kp, vp, bt,
                                            ln + 1 + j)
        np.testing.assert_allclose(multi[:, j], single, atol=1e-6)


# -- 2. the accept rule -------------------------------------------------


def _accept(logits, tokens, num_drafts, temps=None, seeds=None):
    B, K1, _ = logits.shape
    z = jnp.zeros((B,), jnp.int32)
    temps = jnp.zeros((B,), jnp.float32) if temps is None else temps
    seeds = z if seeds is None else seeds
    out, commit = verify_accept(
        jnp.asarray(logits, jnp.float32), jnp.asarray(tokens, jnp.int32),
        jnp.asarray(num_drafts, jnp.int32), seeds, z, temps, z,
        jnp.ones((B,), jnp.float32))
    return np.asarray(out), np.asarray(commit)


def test_accept_prefix_rule(rng):
    V, K1 = 11, 4
    logits = rng.normal(size=(3, K1, V))
    tgt = logits.argmax(-1)                      # greedy targets
    tokens = np.zeros((3, K1), np.int64)
    tokens[0, 1:] = tgt[0, :3]                   # all 3 drafts match
    tokens[1, 1:] = [tgt[1, 0], (tgt[1, 1] + 1) % V, tgt[1, 2]]
    tokens[2, 1:] = (tgt[2, :3] + 1) % V         # none match
    out, commit = _accept(logits, tokens, [3, 3, 3])
    assert list(commit) == [4, 2, 1]
    # emitted tokens are the targets up to and including the correction
    assert list(out[0]) == list(tgt[0])          # 3 accepted + bonus
    assert list(out[1, :2]) == list(tgt[1, :2]) and out[1, 2] == -1
    assert out[2, 0] == tgt[2, 0] and (out[2, 1:] == -1).all()


def test_accept_respects_num_drafts(rng):
    V = 7
    logits = rng.normal(size=(2, 3, V))
    tgt = logits.argmax(-1)
    tokens = np.zeros((2, 3), np.int64)
    tokens[:, 1:] = tgt[:, :2]                   # drafts would all match
    out, commit = _accept(logits, tokens, [0, 1])
    assert list(commit) == [1, 2]                # capped by num_drafts
    assert (out[0, 1:] == -1).all() and out[1, 2] == -1


def test_accept_seeded_matches_sampler(rng):
    """Seeded acceptance couples to the SAME stream the baseline
    sampler draws from: target row j == sample_tokens at step+j."""
    from repro.launch.engine.sampling import sample_tokens

    V, K1 = 13, 3
    logits = jnp.asarray(rng.normal(size=(2, K1, V)), jnp.float32)
    seeds = jnp.asarray([5, 9], jnp.int32)
    temps = jnp.asarray([0.8, 1.2], jnp.float32)
    steps0 = jnp.asarray([2, 0], jnp.int32)
    z = jnp.zeros((2,), jnp.int32)
    ones = jnp.ones((2,), jnp.float32)
    want = np.stack([
        np.asarray(sample_tokens(logits[:, j], seeds, steps0 + j, temps,
                                 z, ones)) for j in range(K1)], axis=1)
    tokens = np.zeros((2, K1), np.int64)
    tokens[:, 1:] = want[:, :K1 - 1]             # drafts == stream draws
    out, commit = verify_accept(logits, jnp.asarray(tokens, jnp.int32),
                                jnp.asarray([2, 2], jnp.int32), seeds,
                                steps0, temps, z, ones)
    assert (np.asarray(commit) == K1).all()
    np.testing.assert_array_equal(np.asarray(out), want)


# -- 3. engine equivalence ---------------------------------------------


@pytest.mark.parametrize("arch", ["olmo_1b", "recurrentgemma_2b"])
@pytest.mark.parametrize("sampling", [GREEDY, SEEDED],
                         ids=["greedy", "seeded"])
def test_spec_engine_bit_identical(rng, arch, sampling):
    model, params = _model(arch)
    prompts = _prompts(model.cfg, rng, repetitive=True) \
        + _prompts(model.cfg, rng, n=2)
    base = Engine(model, params, _cfg())
    want = base.generate(prompts, sampling)
    spec = Engine(model, params, _cfg(spec_tokens=3))
    got = spec.generate(prompts, sampling)
    assert got == want
    st = spec.stats()
    assert isinstance(spec.backend, SpecDecodeBackend)
    assert st["blocks_used"] == 0
    assert st["spec"]["proposed"] >= 0


@pytest.mark.parametrize("arch", ["olmo_1b", "recurrentgemma_2b",
                                  "xlstm_1_3b"])
@pytest.mark.parametrize("sampling", [GREEDY, SEEDED],
                         ids=["greedy", "seeded"])
def test_spec_engine_identical_under_full_rejection(rng, arch, sampling):
    """Adversarial drafts: every window's tail is rejected and rewound,
    so per-slot state (rings, SSM carries) must be committed exactly at
    the accept boundary — outputs still bit-identical, zero leaks."""
    model, params = _model(arch)
    prompts = _prompts(model.cfg, rng)
    base = Engine(model, params, _cfg())
    want = base.generate(prompts, sampling)
    spec = Engine(model, params, _cfg(spec_tokens=3))
    spec.backend.drafter = GarbageDrafter(3)
    got = spec.generate(prompts, sampling)
    assert got == want
    st = spec.stats()
    assert st["spec"]["accepted"] == 0 and st["spec"]["proposed"] > 0
    assert st["blocks_used"] == 0


def test_spec_draft_model_drafter_identical(rng):
    """Draft-model drafter: a small attention-only LM proposes; outputs
    match the baseline regardless of how good its guesses are (here:
    same arch, DIFFERENT weights)."""
    model, params = _model("olmo_1b")
    draft_params = model.init(jax.random.PRNGKey(7))
    prompts = _prompts(model.cfg, rng)
    for sampling in (GREEDY, SEEDED):
        base = Engine(model, params, _cfg())
        want = base.generate(prompts, sampling)
        spec = Engine(model, params, _cfg(
            spec_tokens=2, drafter="draft_model", draft_model=model,
            draft_params=draft_params))
        got = spec.generate(prompts, sampling)
        assert got == want
        assert spec.stats()["blocks_used"] == 0


def test_spec_stop_tokens_mid_window(rng):
    """A stop/eos token emitted mid-window retires the request there;
    extra accepted-but-unemitted tokens are discarded with the slot."""
    model, params = _model("olmo_1b")
    prompts = _prompts(model.cfg, rng, n=4, repetitive=True)
    base = Engine(model, params, _cfg(eos_id=3))
    want = base.generate(prompts, SamplingParams(max_tokens=12,
                                                 stop_token_ids=(5, 9)))
    spec = Engine(model, params, _cfg(eos_id=3, spec_tokens=3))
    got = spec.generate(prompts, SamplingParams(max_tokens=12,
                                                stop_token_ids=(5, 9)))
    assert got == want
    assert spec.stats()["blocks_used"] == 0


# -- 4. scheduler invariants under speculation --------------------------


def test_spec_no_leak_under_preemption(rng):
    """Tiny pool: growth for verify windows forces LIFO preemption and
    rejected-tail trims; every block must come home."""
    model, params = _model("olmo_1b")
    cfg = _cfg(num_slots=4, num_blocks=9, block_size=4, max_len=32,
               spec_tokens=3, watermark_blocks=1)
    base = Engine(model, params, _cfg(num_slots=4, num_blocks=9,
                                      block_size=4, max_len=32))
    prompts = [list(rng.integers(0, model.cfg.vocab_size, 6))
               for _ in range(6)]
    sampling = SamplingParams(max_tokens=20)
    want = base.generate(prompts, sampling)
    spec = Engine(model, params, cfg)
    got = spec.generate(prompts, sampling)
    assert got == want
    st = spec.stats()
    assert st["blocks_used"] == 0
    assert st["spec"]["per_request"], "per-request counters missing"
    # the preemption counter survives alongside the spec section
    assert "preemptions" in st


def test_spec_window_shrinks_before_evicting(rng):
    """When the pool covers plain decode but not a full verify window,
    the slot shrinks its own drafts instead of preempting others."""
    model, params = _model("olmo_1b")
    # 10 usable blocks cover both requests' full PLAIN footprint
    # (2 x blocks_for(7 + 12) = 10) but not always the +3-draft window
    spec = Engine(model, params, _cfg(num_slots=2, num_blocks=11,
                                      block_size=4, max_len=24,
                                      spec_tokens=3))
    prompts = [(list(rng.integers(0, model.cfg.vocab_size, 2)) * 5)[:7]
               for _ in range(2)]
    base = Engine(model, params, _cfg(num_slots=2, num_blocks=11,
                                      block_size=4, max_len=24))
    sampling = SamplingParams(max_tokens=12)
    assert spec.generate(prompts, sampling) == \
        base.generate(prompts, sampling)
    st = spec.stats()
    assert st["preemptions"] == 0, "speculation must not evict"
    assert st["blocks_used"] == 0


@pytest.mark.parametrize("drafter", ["garbage", "ngram", "draft_model"])
def test_spec_window_clamped_at_position_cap(rng, drafter):
    """A slot within K tokens of max_len clamps its draft window (no
    block-table overflow) and pad rows past the cap write to the null
    block, never into the slot's own last real block."""
    model, params = _model("olmo_1b")
    kw = dict(num_slots=2, num_blocks=24, block_size=4, max_len=32)
    base = Engine(model, params, _cfg(**kw))
    prompts = [[1, 2] * 6, [3, 4] * 6]
    sp = SamplingParams(max_tokens=20)        # 12 + 20 == max_len exactly
    want = base.generate(prompts, sp)
    skw = dict(kw, spec_tokens=4)
    if drafter == "draft_model":
        skw.update(drafter="draft_model", draft_model=model,
                   draft_params=model.init(jax.random.PRNGKey(3)))
    spec = Engine(model, params, _cfg(**skw))
    if drafter == "garbage":
        spec.backend.drafter = GarbageDrafter(4)
    got = spec.generate(prompts, sp)
    assert got == want
    assert spec.stats()["blocks_used"] == 0


def test_draft_model_cache_has_no_holes(rng):
    """Full-accept windows leave the draft cache one token behind the
    target; the catch-up feed must fill that position — every position
    below the draft's frontier holds real K/V (a hole would silently
    erode proposal quality for the rest of the request)."""
    model, params = _model("olmo_1b")
    spec = Engine(model, params, _cfg(
        num_blocks=32, max_len=64, spec_tokens=3, drafter="draft_model",
        draft_model=model, draft_params=params))   # self-draft: accepts
    spec.add_request([5, 9, 5, 9, 5], SamplingParams(max_tokens=40))
    for _ in range(7):
        if spec.has_work:
            spec.step()
    dr = spec.backend.drafter
    pos = int(dr.pos[0])
    assert pos > 10, "window never advanced — test premise broken"
    leaf = jax.tree.leaves(dr.cache)[0]            # (L, B, S, Hkv, D)
    norms = np.linalg.norm(
        np.asarray(leaf[0, 0], np.float32).reshape(leaf.shape[2], -1),
        axis=1)
    holes = [p for p in range(pos) if norms[p] == 0.0]
    assert not holes, f"unwritten draft-cache positions: {holes}"


def test_spec_stats_counters(rng):
    model, params = _model("olmo_1b")
    spec = Engine(model, params, _cfg(spec_tokens=3))
    prompts = _prompts(model.cfg, rng, n=3, repetitive=True)
    spec.generate(prompts, SamplingParams(max_tokens=16))
    st = spec.stats()["spec"]
    assert st["spec_tokens"] == 3 and st["steps"] > 0
    assert st["emitted"] >= st["steps"]
    assert 0.0 <= st["accept_rate"] <= 1.0
    per = st["per_request"]
    assert len(per) == 3
    assert sum(r["proposed"] for r in per.values()) == st["proposed"]
    assert sum(r["accepted"] for r in per.values()) == st["accepted"]
    # handle-level counters mirror the aggregate
    h = spec.finished[0]
    assert h.num_draft_proposed == per[h.uid]["proposed"]


# -- 5. drafters --------------------------------------------------------


def test_ngram_drafter_lookup():
    d = NgramDrafter(k=3, max_ngram=3)
    # most recent match with a FULL continuation wins
    assert d.lookup([1, 2, 3, 9, 1, 2, 3, 7, 8, 1, 2, 3]) == [7, 8, 1]
    # periodic text: an earlier period supplies the full draft width
    assert d.lookup([5, 5, 5, 5]) == [5, 5, 5]
    assert d.lookup([1, 2, 3, 4]) == []          # no repetition
    assert d.lookup([4]) == []                   # too short
    # falls back to shorter suffixes / partial continuations
    assert d.lookup([7, 1, 9, 2, 9]) == [2, 9]


def test_ngram_acceptance_on_repetitive_prompts(rng):
    """The self-drafting claim: on repetitive text the ngram drafter's
    acceptance rate is high and tokens/step rises accordingly."""
    model, params = _model("olmo_1b")
    spec = Engine(model, params, _cfg(spec_tokens=4))
    prompts = _prompts(model.cfg, rng, n=4, repetitive=True)
    spec.generate(prompts, SamplingParams(max_tokens=24))
    st = spec.stats()["spec"]
    assert st["accept_rate"] >= 0.5, st
    assert st["emitted_per_step"] > 1.5, st


def test_spec_config_validation(rng):
    model, params = _model("olmo_1b")
    with pytest.raises(ValueError, match="paged"):
        Engine(model, params, EngineConfig(backend="static",
                                           spec_tokens=2))
    with pytest.raises(ValueError, match="draft_model"):
        Engine(model, params, _cfg(spec_tokens=2, drafter="draft_model"))
    with pytest.raises(ValueError, match="unknown drafter"):
        Engine(model, params, _cfg(spec_tokens=2, drafter="nope"))
    # recurrent draft models cannot roll back by pointer rewind
    rg, rg_params = _model("recurrentgemma_2b")
    rg_cfg = dataclasses.replace(rg.cfg,
                                 vocab_size=model.cfg.vocab_size)
    with pytest.raises(ValueError, match="attention-only"):
        Engine(model, params, _cfg(
            spec_tokens=2, drafter="draft_model",
            draft_model=Model(rg_cfg), draft_params=rg_params))
