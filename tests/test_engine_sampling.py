"""Sampling semantics for the serving engine (satellite coverage).

The vectorized on-device sampler (launch/engine/sampling.py) must obey
the classical limits — temperature -> 0 is argmax, top-k=1 is argmax,
top-p bounds the nucleus mass — and its per-request RNG streams must
make sampled serving outputs reproducible and independent of admission
order, slot placement and preemption. Property sweeps run through the
hypothesis-compat shim (fixed-seed fallback when hypothesis is absent).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.launch.engine import (Engine, EngineConfig, SamplingParams,
                                 sample_tokens)
from repro.models.model import Model

V = 64


def _sample(logits, *, seed=0, step=0, temp=1.0, top_k=0, top_p=1.0):
    out = sample_tokens(jnp.asarray(logits, jnp.float32)[None],
                        jnp.asarray([seed], jnp.int32),
                        jnp.asarray([step], jnp.int32),
                        jnp.asarray([temp], jnp.float32),
                        jnp.asarray([top_k], jnp.int32),
                        jnp.asarray([top_p], jnp.float32))
    return int(out[0])


# -- classical limits ----------------------------------------------------


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_temperature_zero_is_argmax(seed):
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(V,)) * 3
    assert _sample(logits, seed=seed, temp=0.0) == int(np.argmax(logits))


@given(st.integers(0, 10_000), st.integers(0, 63))
@settings(max_examples=20, deadline=None)
def test_top_k_one_is_argmax(seed, step):
    """top_k=1 leaves only the argmax token at ANY temperature."""
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(V,)) * 3
    got = _sample(logits, seed=seed, step=step, temp=1.7, top_k=1)
    assert got == int(np.argmax(logits))


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_top_k_restricts_support(seed):
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(V,)) * 3
    k = 5
    topk = set(np.argsort(logits)[-k:])
    for step in range(16):
        assert _sample(logits, seed=seed, step=step, temp=1.0,
                       top_k=k) in topk


@given(st.integers(0, 10_000), st.floats(0.05, 0.95))
@settings(max_examples=15, deadline=None)
def test_top_p_mass_bound(seed, p):
    """Every draw lies in the smallest descending-probability prefix
    whose cumulative mass reaches p (ties at the boundary allowed; a
    small epsilon absorbs the sampler's f32 cumsum vs this f64 check)."""
    rng = np.random.default_rng(seed)
    temp = 0.9
    logits = rng.normal(size=(V,)) * 2.5
    probs = np.exp(logits / temp - np.max(logits / temp))
    probs /= probs.sum()
    order = np.argsort(-probs)
    cum = np.cumsum(probs[order])
    m = min(int(np.sum(cum < p + 1e-4)) + 1, V)   # nucleus size bound
    floor = probs[order][m - 1] - 1e-6            # ties at boundary OK
    nucleus = {int(i) for i in range(V) if probs[i] >= floor}
    assert len(nucleus) < V or p > cum[-2]        # the bound has teeth
    for step in range(12):
        tok = _sample(logits, seed=seed, step=step, temp=temp, top_p=p)
        assert tok in nucleus, (tok, sorted(nucleus))


def test_stream_determinism_and_independence():
    """Same (seed, step) -> same draw; the stream varies over steps; two
    slots sampled together draw independently per-slot."""
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(2, V)).astype(np.float32)
    args = dict(temps=jnp.asarray([1.0, 1.0], jnp.float32),
                top_ks=jnp.asarray([0, 0], jnp.int32),
                top_ps=jnp.asarray([1.0, 1.0], jnp.float32))
    a = sample_tokens(jnp.asarray(logits), jnp.asarray([3, 3], jnp.int32),
                      jnp.asarray([0, 0], jnp.int32), **args)
    b = sample_tokens(jnp.asarray(logits), jnp.asarray([3, 3], jnp.int32),
                      jnp.asarray([0, 0], jnp.int32), **args)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    # identical logits rows + identical seeds -> identical draws per row
    same = sample_tokens(jnp.asarray(np.stack([logits[0], logits[0]])),
                         jnp.asarray([3, 3], jnp.int32),
                         jnp.asarray([0, 0], jnp.int32), **args)
    assert int(same[0]) == int(same[1])
    # the stream moves: over many steps the draw must change sometime
    draws = {_sample(logits[0], seed=3, step=s) for s in range(24)}
    assert len(draws) > 1


# -- engine-level semantics ---------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _paged(model, params, **kw):
    base = dict(backend="paged", num_slots=2, block_size=4, num_blocks=33,
                max_len=64)
    base.update(kw)
    return Engine(model, params, EngineConfig(**base))


def test_stop_token_truncation(smoke_model, rng):
    """A stop token retires the request mid-stream, is stripped from the
    output, and frees capacity for queued work."""
    cfg, model, params = smoke_model
    prompt = list(rng.integers(0, cfg.vocab_size, 6))
    full = _paged(model, params).generate(
        [prompt], SamplingParams(max_tokens=6))[0]
    assert len(full) == 6
    stop = full[3]
    first = full.index(stop)                      # may repeat earlier
    got = _paged(model, params).generate(
        [prompt], SamplingParams(max_tokens=6,
                                 stop_token_ids=(stop,)))[0]
    assert got == full[:first]


def test_sampled_outputs_independent_of_admission_order(smoke_model, rng):
    """Satellite acceptance: SAMPLED (not just greedy) outputs are a pure
    function of (params, prompt, SamplingParams) — permuting submissions
    and changing slot count must reproduce every request bit-exactly,
    including under preemption pressure."""
    cfg, model, params = smoke_model
    work = [(list(map(int, rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(3, 10))))),
             SamplingParams(max_tokens=8, temperature=8.0, top_k=24,
                            top_p=0.95, seed=100 + i))
            for i in range(5)]
    a = _paged(model, params, num_slots=1).generate(
        [w[0] for w in work], [w[1] for w in work])
    order = [4, 2, 0, 3, 1]
    b = _paged(model, params, num_slots=4).generate(
        [work[i][0] for i in order], [work[i][1] for i in order])
    for j, i in enumerate(order):
        assert b[j] == a[i], f"request {i} diverged under reordering"
    # tight pool: preemption + recompute must not disturb the streams
    tight = _paged(model, params, num_slots=3, num_blocks=9)
    c = tight.generate([w[0] for w in work], [w[1] for w in work])
    assert c == a
    assert tight.stats()["blocks_used"] == 0


def test_seed_selects_the_stream(smoke_model, rng):
    """Different seeds draw different continuations (overwhelmingly);
    the same seed reproduces."""
    cfg, model, params = smoke_model
    prompt = list(rng.integers(0, cfg.vocab_size, 5))
    # high temperature: the random-init smoke model is sharply peaked,
    # so small temps still collapse every seed onto the argmax chain
    sp = [SamplingParams(max_tokens=10, temperature=8.0, seed=s)
          for s in (0, 1, 0)]
    outs = _paged(model, params, num_slots=3).generate([prompt] * 3, sp)
    assert outs[0] == outs[2]
    assert outs[0] != outs[1]


def test_large_seed_folds_to_int32(smoke_model, rng):
    """Regression: seeds beyond int32 (time-based seeds, the legacy
    shims' derived seed*100_003+i) must fold instead of overflowing the
    device-side int32 param arrays, and folding must be consistent
    between the prefill and decode sampling paths."""
    cfg, model, params = smoke_model
    prompt = list(rng.integers(0, cfg.vocab_size, 5))
    sp_big = SamplingParams(max_tokens=6, temperature=8.0,
                            seed=2**40 + 7)
    sp_folded = SamplingParams(max_tokens=6, temperature=8.0,
                               seed=(2**40 + 7) & 0x7FFFFFFF)
    a = _paged(model, params).generate([prompt], sp_big)
    b = _paged(model, params).generate([prompt], sp_folded)
    assert a == b and len(a[0]) == 6


def test_static_backend_samples_identically(smoke_model, rng):
    """The vectorized sampler behaves identically behind both backends:
    same seeds, same prompts -> same stochastic outputs."""
    cfg, model, params = smoke_model
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (4, 9)]
    sp = [SamplingParams(max_tokens=7, temperature=8.0, top_k=16, seed=s)
          for s in (11, 12)]
    a = _paged(model, params).generate(prompts, sp)
    b = Engine(model, params,
               EngineConfig(backend="static", num_slots=2,
                            max_len=64)).generate(prompts, sp)
    assert a == b
