"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; multi-device tests re-exec via subprocess."""

import os
import sys

import numpy as np
import pytest

# Make `import _hypothesis_compat` work regardless of rootdir/invocation.
sys.path.insert(0, os.path.dirname(__file__))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    # f64 needed by VRP/solver tests; models pass explicit dtypes so this
    # is safe globally.
    import jax

    jax.config.update("jax_enable_x64", True)
    # `slow` marker registration + default deselection live in pytest.ini
