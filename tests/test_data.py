"""Data pipeline: determinism, sharding, resumability, file source."""

import numpy as np
import pytest

from repro.data.pipeline import DataConfig, FileTokens, SyntheticLM, make_source


def test_batch_deterministic_per_step():
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=4, seed=3)
    src = SyntheticLM(cfg)
    a = src.batch_at(5)
    b = src.batch_at(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = src.batch_at(6)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_targets_shifted():
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=2)
    b = SyntheticLM(cfg).batch_at(0)
    # affine recurrence: target t == token t+1; check internal consistency
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["targets"][:, :-1]))


def test_sharded_batches_partition_global():
    cfg = DataConfig(vocab_size=31, seq_len=8, global_batch=8, seed=1)
    src = SyntheticLM(cfg)
    shards = [src.batch_at(2, shard=i, n_shards=4) for i in range(4)]
    for s in shards:
        assert s["tokens"].shape == (2, 8)
    # different shards see different data
    assert not np.array_equal(np.asarray(shards[0]["tokens"]),
                              np.asarray(shards[1]["tokens"]))


def test_file_tokens(tmp_path):
    path = tmp_path / "toks.bin"
    arr = (np.arange(10000) % 251).astype(np.uint16)
    arr.tofile(path)
    cfg = DataConfig(vocab_size=251, seq_len=16, global_batch=4,
                     path=str(path))
    src = make_source(cfg)
    assert isinstance(src, FileTokens)
    b0 = src.batch_at(0)
    b0_again = src.batch_at(0)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(b0_again["tokens"]))
    assert b0["tokens"].shape == (4, 16)
    assert int(b0["tokens"].max()) < 251
