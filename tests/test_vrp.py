"""VRP expansion arithmetic: exactness, accuracy, precision scaling."""

from fractions import Fraction

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import vrp
from repro.core.precision import F64, VP128, VP256, VP512, PrecisionEnv, get_env

# EFT exactness holds for NORMAL floats; XLA:CPU (and real TPUs) flush
# subnormals, so error terms below ~2^-1022 are lost — a documented
# hardware limitation (DESIGN.md §2.4), same as on the silicon VRP whose
# extended formats also bound the exponent (18 bits).
finite = st.floats(min_value=-1e30, max_value=1e30, allow_nan=False,
                   allow_infinity=False, allow_subnormal=False).filter(
                       lambda v: v == 0 or abs(v) > 1e-100)


@given(finite, finite)
@settings(max_examples=200, deadline=None)
def test_two_sum_exact(a, b):
    s, e = vrp.two_sum(jnp.float64(a), jnp.float64(b))
    assert Fraction(float(s)) + Fraction(float(e)) == Fraction(a) + Fraction(b)


@given(finite, finite)
@settings(max_examples=200, deadline=None)
def test_two_prod_exact(a, b):
    p, e = vrp.two_prod(jnp.float64(a), jnp.float64(b))
    if np.isfinite(float(p)):
        assert (Fraction(float(p)) + Fraction(float(e))
                == Fraction(a) * Fraction(b))


@given(st.lists(st.floats(min_value=-1e30, max_value=1e30, allow_nan=False,
                          allow_infinity=False, allow_subnormal=False)
                .filter(lambda v: v == 0 or abs(v) > 1e-100),
                min_size=2, max_size=24))
@settings(max_examples=100, deadline=None)
def test_renormalize_preserves_exact_value(xs):
    """EFT invariant: renorm never changes the exact sum when K >= M."""
    t = jnp.array(xs)
    out = vrp.renormalize(t, K=len(xs) + 2)
    exact_in = sum(Fraction(float(x)) for x in xs)
    exact_out = sum(Fraction(float(x)) for x in np.array(out))
    assert exact_in == exact_out


@pytest.mark.parametrize("env,bits", [(VP128, 100), (VP256, 200), (VP512, 400)])
def test_dot_accuracy_scales_with_precision(env, bits):
    """Cancellation-heavy dot: error must shrink ~2^-bits."""
    rng = np.random.default_rng(0)
    n = 2048
    x = rng.normal(size=n) * 1e10
    y = rng.normal(size=n)
    x[::2] = -x[1::2] * (1 + 1e-16)
    exact = sum(Fraction(float(a)) * Fraction(float(b)) for a, b in zip(x, y))
    got = sum(Fraction(float(t)) for t in
              np.array(vrp.dot(jnp.array(x), jnp.array(y), env)))
    err = abs(got - exact)
    scale = abs(exact) or Fraction(1)
    assert err / scale < Fraction(2) ** -bits


def test_mul_div_sqrt_roundtrip():
    env = VP256
    x = vrp.from_float(jnp.float64(3.14159265358979), env)
    y = vrp.from_float(jnp.float64(2.71828182845905), env)
    q = vrp.div(x, y, env)
    back = vrp.mul(q, y, env)
    resid = vrp.to_float(vrp.sub(back, x, env))
    assert abs(float(resid)) < 1e-60
    s = vrp.sqrt(x, env)
    resid = vrp.to_float(vrp.sub(vrp.mul(s, s, env), x, env))
    assert abs(float(resid)) < 1e-60


def test_precision_env_presets():
    assert VP512.significand_bits >= 512
    assert VP128.significand_bits == 106
    assert get_env("vp128") is VP128
    with pytest.raises(ValueError):
        PrecisionEnv(compute_terms=0)
    with pytest.raises(ValueError):
        PrecisionEnv(compute_terms=2, store_terms=3)


def test_storage_vs_compute_format():
    """The paper's memory-format/compute-format split."""
    env = PrecisionEnv(compute_terms=4, store_terms=2)
    st_env = env.storage()
    assert st_env.K == 2
    x = vrp.from_float(jnp.float64(1.0) / 3.0, env)
    stored = x[..., :st_env.K]
    assert stored.shape[-1] == 2


def test_matvec_extended():
    rng = np.random.default_rng(1)
    A = rng.normal(size=(16, 16))
    x = rng.normal(size=16)
    y = vrp.matvec(jnp.array(A), vrp.from_float(jnp.array(x), VP128), VP128)
    ref = A.astype(np.float64) @ x
    assert np.allclose(np.array(vrp.to_float(y)), ref, rtol=1e-14)


def test_f32_base_dtype():
    """TPU-native extended precision: f32 pairs (~48 bits)."""
    env = PrecisionEnv(compute_terms=2, base_dtype="float32")
    rng = np.random.default_rng(2)
    x = rng.normal(size=512).astype(np.float32) * 1e4
    y = rng.normal(size=512).astype(np.float32)
    exact = float(np.dot(x.astype(np.float64), y.astype(np.float64)))
    naive = float(jnp.dot(jnp.array(x), jnp.array(y)))
    got = float(vrp.to_float(vrp.dot(jnp.array(x), jnp.array(y), env)
                             .astype(jnp.float64)))
    assert abs(got - exact) < abs(naive - exact) / 10 + 1e-6
