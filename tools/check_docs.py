"""Docs lane: markdown link checker + serving-surface docstring check.

Two cheap, dependency-free gates so the docs cannot rot:

1. **Links** — every relative markdown link in the repo's ``*.md``
   files (root + ``docs/``) must point at a file that exists. External
   (``http(s)://``, ``mailto:``) and pure-anchor links are skipped;
   ``#fragment`` suffixes are stripped before the existence check.
2. **Docstrings** (pydocstyle-style, scoped to ``launch/engine/``) —
   every module, public class and public function/method in the
   serving package must carry a docstring, and the documented public
   API classes must use NumPy-style sections (``Parameters`` /
   ``Attributes`` / ``Notes`` / ... underlined with dashes), because
   docs/serving.md defers to them as the reference.

Run: python tools/check_docs.py   (CI runs it in the tier-1 job)
"""

from __future__ import annotations

import ast
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
ENGINE = REPO / "src" / "repro" / "launch" / "engine"
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SECTION_RE = re.compile(
    r"^\s*(Parameters|Returns|Yields|Raises|Attributes|Methods|Notes|"
    r"Examples|See Also)\n\s*-{3,}", re.MULTILINE)
# the public serving surface docs/serving.md defers to — these must
# carry NumPy-style sections, not just any docstring
NUMPY_STYLE_REQUIRED = {
    "Engine", "Request", "SamplingParams", "RequestHandle",
    "RequestOutput", "EngineConfig", "ReplicaSet", "SpecDecodeBackend",
    "DisaggregatedEngine",
}


def check_links() -> list[str]:
    errors = []
    md_files = sorted(REPO.glob("*.md")) + sorted(REPO.glob("docs/*.md"))
    for md in md_files:
        for m in LINK_RE.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(REPO)}: broken link "
                              f"-> {target}")
    return errors


def _missing_doc(node) -> bool:
    doc = ast.get_docstring(node)
    return not doc or not doc.strip()


def check_docstrings() -> list[str]:
    errors = []
    found = set()
    for py in sorted(ENGINE.glob("*.py")):
        rel = py.relative_to(REPO)
        tree = ast.parse(py.read_text())
        if _missing_doc(tree):
            errors.append(f"{rel}: missing module docstring")
        # module-level defs + class-level methods only — closures inside
        # function bodies are implementation detail, not public surface
        nodes = list(tree.body)
        nodes += [n for cls in tree.body if isinstance(cls, ast.ClassDef)
                  for n in cls.body]
        for node in nodes:
            if not isinstance(node, (ast.ClassDef, ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if node.name.startswith("_"):
                continue
            if _missing_doc(node):
                errors.append(f"{rel}:{node.lineno}: public "
                              f"{type(node).__name__.lower()} "
                              f"`{node.name}` has no docstring")
                continue
            if isinstance(node, ast.ClassDef) \
                    and node.name in NUMPY_STYLE_REQUIRED:
                found.add(node.name)
                if not SECTION_RE.search(ast.get_docstring(node)):
                    errors.append(
                        f"{rel}:{node.lineno}: `{node.name}` is part of "
                        "the documented serving surface and needs "
                        "NumPy-style sections (Parameters/Attributes/"
                        "Notes/... underlined with ---)")
    for name in sorted(NUMPY_STYLE_REQUIRED - found):
        errors.append(f"launch/engine: documented class `{name}` not "
                      "found — update tools/check_docs.py or the docs")
    return errors


def main() -> None:
    errors = check_links() + check_docstrings()
    if errors:
        for e in errors:
            print(f"DOCS: {e}", file=sys.stderr)
        sys.exit(1)
    print("docs lane: links + engine docstrings ok")


if __name__ == "__main__":
    main()
